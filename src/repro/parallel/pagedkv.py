"""Sharded paged serving: ``kv_pages``-partitioned pools under shard_map.

The paged KV pool's leading (P) dim carries the ``kv_pages`` logical axis
(``repro.parallel.sharding.default_rules`` maps it to the ``model`` mesh
axis), so an inference mesh of n chips pins P/n pages each — pool HBM
scales *down* with the mesh instead of being replicated.  Chip c owns the
global page-id range ``[c*P/n, (c+1)*P/n)``; the (B, M) page table and the
single-token q/K/V stay replicated (B·M int32 + one token per slot — noise
next to the pool).

One decode step = one shard_map region per layer:

1. **Local scatter-write** — the chip owning the write page
   ``table[b, pos // page]`` commits the new K/V row at its local flat
   index; every other chip's write is ``mode="drop"``-discarded
   (``repro.models.attention.scatter_paged_kv_local``).
2. **Local partial attention** — each chip attends only to pages inside
   its window, treating non-local pages exactly like dead pages:
   the Pallas kernel's index map redirects them to local page 0 and
   ``pl.when`` skips their compute (``kernels.ops.paged_decode_partials``),
   and the XLA gather twin masks them to NEG_INF
   (``attention.paged_gather_partials``) so the same merge covers CPU.
   Either way the chip emits the raw online-softmax triple (acc, l, m).
3. **Partial-softmax merge** — one pmax + two psums reconstruct the exact
   softmax over the union of chips (``attention.merge_paged_partials``):
   ``out = psum(acc · exp(m - pmax(m))) / psum(l · exp(m - pmax(m)))``.

The merge moves O(B·KV·G·(D+2)) fp32 per layer over ICI — independent of
both the pool width and the sequence length, the flash-decoding property
that makes the page dimension the right thing to shard.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.parallel.mesh import mesh_axis_size
from repro.parallel.sharding import default_rules, shard_map, spec_for

# logical axes of a per-layer-stacked page pool (L, P, page, KV, D); only
# kv_pages resolves to a mesh axis — the page/head/dim axes stay local so
# each chip holds whole pages (the kernel's block unit)
POOL_LOGICAL_AXES = ("layers", "kv_pages", None, None, None)

# the int8 page format's scale arrays (L, P, page, KV) drop the D axis but
# keep the page-partitioned leading dims: each chip holds exactly the scales
# of the pages it owns, so local dequant never reads a remote scale
SCALE_LOGICAL_AXES = POOL_LOGICAL_AXES[:4]


def chip_of_page(pid: int, pages_per_chip: int) -> int:
    """The chip owning global page id ``pid`` under the contiguous-range
    P/n split (chip c owns ``[c*P/n, (c+1)*P/n)``).  Shared by the
    allocator's per-chip free lists and the chip-failure drain path, so
    page->chip routing can never disagree between alloc and recovery."""
    return pid // pages_per_chip


def chip_page_range(chip: int, pages_per_chip: int) -> range:
    """The global page-id range chip ``chip`` owns (scratch page 0 included
    when chip 0 — callers that mean *usable* pages must skip id 0)."""
    return range(chip * pages_per_chip, (chip + 1) * pages_per_chip)


def kv_pool_spec(mesh, pool_shape, rules=None,
                 axis: str = None) -> PartitionSpec:
    """PartitionSpec for a (L, P, page, KV, D) pool: ``kv_pages`` -> mesh.

    ``axis`` overrides the rule's target mesh axis (PagedCache passes its
    ``kv_axis`` so a non-default axis name still shards the pool)."""
    rules = dict(rules if rules is not None
                 else default_rules(mesh.axis_names))
    if axis is not None:
        rules["kv_pages"] = axis
    return spec_for(POOL_LOGICAL_AXES, pool_shape, rules, mesh)


def kv_pool_sharding(mesh, pool_shape, rules=None,
                     axis: str = None) -> NamedSharding:
    return NamedSharding(mesh, kv_pool_spec(mesh, pool_shape, rules, axis))


def kv_scale_spec(mesh, scale_shape, rules=None,
                  axis: str = None) -> PartitionSpec:
    """PartitionSpec for a (L, P, page, KV) scale array: same ``kv_pages``
    partitioning as its pool, minus the D axis."""
    rules = dict(rules if rules is not None
                 else default_rules(mesh.axis_names))
    if axis is not None:
        rules["kv_pages"] = axis
    return spec_for(SCALE_LOGICAL_AXES, scale_shape, rules, mesh)


def kv_scale_sharding(mesh, scale_shape, rules=None,
                      axis: str = None) -> NamedSharding:
    return NamedSharding(mesh, kv_scale_spec(mesh, scale_shape, rules, axis))


def sharded_paged_decode_attention(mesh, axis: str, q, k_new, v_new,
                                   k_pool, v_pool, page_table, positions,
                                   decode_impl: str = "gather",
                                   k_scale=None, v_scale=None):
    """One layer's sharded paged decode: scatter the new token into the
    owning chip's pool shard, compute per-chip softmax partials, merge.

    q: (B, 1, KV, G, D); k_new/v_new: (B, 1, KV, D) this step's projected
    K/V; pools: (P, page, KV, D) GLOBAL views sharded P/n over ``axis``;
    page_table: (B, M) global ids; positions: (B,).  Returns
    (y (B,1,KV,G,D), new_k_pool, new_v_pool) with the pools still sharded.

    ``decode_impl`` picks the per-chip partial producer: ``"pallas"`` (the
    page-table-walking kernel with its local window) or ``"gather"`` (XLA
    local-masked gather) — both feed the identical merge, so the two impls
    stay in parity sharded exactly as they do on one chip.

    ``k_scale``/``v_scale`` (quantized int8 pools): (P, page, KV) fp32
    scale arrays sharded exactly like the pools.  The new token's float K/V
    is quantized *inside* the shard_map body (replicated, deterministic —
    every chip computes the identical (q, scale) pair) and the owning chip
    commits both the int8 row and its scale with the same ``mode="drop"``
    routing; the partial producers then dequantize locally.  Returns a
    5-tuple ``(y, k_pool, v_pool, k_scale, v_scale)``."""
    from repro.kernels import ops as kops
    from repro.models import attention as attn

    n = mesh_axis_size(mesh, axis)
    p_total = k_pool.shape[0]
    assert p_total % n == 0, (
        f"page pool P={p_total} must divide the {axis!r} axis ({n}); "
        "PagedCache pads the pool up to a multiple of the mesh size")
    pn = p_total // n
    quantized = k_scale is not None
    assert quantized == (v_scale is not None), "k/v scales travel together"

    def partials(q, kp, vp, pt, pos, off, ks, vs):
        if decode_impl == "pallas":
            return kops.paged_decode_partials(q, kp, vp, pt, pos, off,
                                              k_scale=ks, v_scale=vs)
        assert decode_impl == "gather", decode_impl
        return attn.paged_gather_partials(q, kp, vp, pt, pos, off,
                                          k_scale=ks, v_scale=vs)

    def body(q, kn, vn, pt, pos, kp, vp):
        off = (jax.lax.axis_index(axis) * pn).astype(jnp.int32)
        kp = attn.scatter_paged_kv_local(kp, kn, pt, pos, off)
        vp = attn.scatter_paged_kv_local(vp, vn, pt, pos, off)
        acc, l, m = partials(q, kp, vp, pt, pos, off, None, None)
        y = attn.merge_paged_partials(acc, l, m, axis).astype(q.dtype)
        return y, kp, vp

    def body_quant(q, kn, vn, pt, pos, kp, vp, ks, vs):
        from repro.kernels.quant import quantize_kv
        off = (jax.lax.axis_index(axis) * pn).astype(jnp.int32)
        qk, sk = quantize_kv(kn)
        qv, sv = quantize_kv(vn)
        kp = attn.scatter_paged_kv_local(kp, qk, pt, pos, off)
        vp = attn.scatter_paged_kv_local(vp, qv, pt, pos, off)
        ks = attn.scatter_paged_kv_local(ks, sk, pt, pos, off)
        vs = attn.scatter_paged_kv_local(vs, sv, pt, pos, off)
        acc, l, m = partials(q, kp, vp, pt, pos, off, ks, vs)
        y = attn.merge_paged_partials(acc, l, m, axis).astype(q.dtype)
        return y, kp, vp, ks, vs

    rep = PartitionSpec()
    sh = PartitionSpec(axis)
    if quantized:
        fn = shard_map(body_quant, mesh=mesh,
                       in_specs=(rep, rep, rep, rep, rep, sh, sh, sh, sh),
                       out_specs=(rep, sh, sh, sh, sh), check_vma=False)
        return fn(q, k_new, v_new, page_table, positions, k_pool, v_pool,
                  k_scale, v_scale)
    fn = shard_map(body, mesh=mesh,
                   in_specs=(rep, rep, rep, rep, rep, sh, sh),
                   out_specs=(rep, sh, sh), check_vma=False)
    return fn(q, k_new, v_new, page_table, positions, k_pool, v_pool)
