"""Expert-parallel MoE via shard_map + all_to_all (§Perf arctic it2).

The pure-GSPMD capacity dispatch gathers expert outputs from model-axis
shards with (B,S,d)-sized f32 all-reduces per routing slot per direction
(~790 GB/step/device on arctic).  The production EP pattern exchanges only
the *dispatched token slots*:

  per device: route local tokens -> scatter into an (E, C, d) send buffer
  (expert-major) -> all_to_all over ``model`` (each shard keeps its E/16
  experts' slots) -> local expert GEMMs -> all_to_all back -> local combine.

Wire bytes/device/step ≈ 2 · T_loc · k · cf · d · 2B  (bf16, both hops) —
for arctic train_4k ≈ 2·65536·2·1.25·7168·2 ≈ 4.7 GB/layer vs ~22 GB of f32
AR in the GSPMD form.  The arctic dense-residual FFN rides in the same
shard_map with a bf16-psum TP down-projection.

Capacity grouping is per-device (G = data shards), the standard GShard
choice at scale; gates/keep masks stay local so combine needs no collective.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name
from jax.sharding import PartitionSpec as P

from repro.models.common import activation
from repro.parallel.sharding import current_context, shard_map
from repro.parallel.tpmm import TP_SAVE_NAME


def moe_ffn_ep(p, cfg, x, axis: str = "model"):
    """Drop-in for models.moe.moe_ffn under a sharding context.
    x: (B, S, d).  Returns (y, aux_loss)."""
    ctx = current_context()
    n_exp = cfg.num_experts
    if ctx is None:
        from repro.models.moe import moe_ffn
        return moe_ffn(p, cfg, x)
    mesh, rules = ctx
    if axis not in mesh.shape or n_exp % mesh.shape[axis] != 0:
        from repro.models.moe import moe_ffn
        return moe_ffn(p, cfg, x)
    n_sh = mesh.shape[axis]
    dp = rules.get("batch")
    dp_axes = tuple(a for a in (dp if isinstance(dp, tuple) else (dp,))
                    if a in mesh.shape) if dp else ()
    k = cfg.experts_per_token
    e_loc = n_exp // n_sh
    d = cfg.d_model
    f = cfg.moe_d_ff
    act = activation(cfg.act)
    dtype = jnp.dtype(cfg.dtype)
    data_ok = "data" in mesh.shape and d % mesh.shape["data"] == 0
    wspec = P(axis, "data" if data_ok else None, None)

    has_dense = "dense" in p
    dense_ok = has_dense and cfg.d_ff % n_sh == 0

    def body(x_loc, router_w, wi, wg, wo, dwi, dwg, dwo):
        b_loc, s, _ = x_loc.shape
        t_all = b_loc * s
        # x is replicated over the model axis inside this shard_map; each
        # model column routes only its token slice (otherwise all 16 peers
        # send identical buffers -> 16x redundant expert work; observed as
        # 5x flops + 6x a2a bytes in §Perf arctic it2, fixed in it3)
        assert t_all % n_sh == 0
        t = t_all // n_sh
        me = jax.lax.axis_index(axis)
        xf = jax.lax.dynamic_slice_in_dim(x_loc.reshape(t_all, d),
                                          me * t, t, axis=0)
        cap = max(math.ceil(t * k / n_exp * cfg.capacity_factor), k)

        # ------- routing (local tokens, full router) -------------------------
        logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                            router_w.astype(jnp.float32))
        probs = jax.nn.softmax(logits, axis=-1)
        gates, ids = jax.lax.top_k(probs, k)
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
        density = jnp.mean(jax.nn.one_hot(ids[..., 0], n_exp,
                                          dtype=jnp.float32), axis=0)
        aux = jnp.mean(density * jnp.mean(probs, axis=0)) * (n_exp * n_exp)
        aux = jax.lax.pmean(aux, dp_axes + (axis,) if dp_axes else (axis,))

        # ------- capacity positions (slot-major priority) --------------------
        ids_sm = ids.T.reshape(k * t)
        onehot = jax.nn.one_hot(ids_sm, n_exp, dtype=jnp.int32)
        pos = jnp.take_along_axis(jnp.cumsum(onehot, 0) - onehot,
                                  ids_sm[:, None], axis=-1)[:, 0]
        pos = pos.reshape(k, t).T                       # (t, k)
        keep = (pos < cap).astype(dtype) * (gates > 0).astype(dtype)
        flat_idx = ids * cap + jnp.minimum(pos, cap - 1)

        # ------- dispatch scatter + all_to_all to expert owners --------------
        buf = jnp.zeros((n_exp * cap, d), dtype)
        for j in range(k):
            buf = buf.at[flat_idx[:, j]].add(xf * keep[:, j, None])
        send = buf.reshape(n_sh, e_loc * cap, d)
        # tiled a2a: (n_sh, e_loc*cap, d) -> (1, n_sh*e_loc*cap, d) with the
        # received axis ordered [src][e][c]; regroup expert-major
        recv = jax.lax.all_to_all(send, axis, split_axis=0, concat_axis=1,
                                  tiled=True)
        recv = recv.reshape(n_sh, e_loc, cap, d).swapaxes(0, 1) \
                   .reshape(e_loc, n_sh * cap, d)

        # ------- local expert GEMMs ------------------------------------------
        if data_ok:
            wi_l = jax.lax.all_gather(wi, "data", axis=1, tiled=True)
            wg_l = jax.lax.all_gather(wg, "data", axis=1, tiled=True)
            wo_l = jax.lax.all_gather(wo, "data", axis=2, tiled=True)
        else:
            wi_l, wg_l, wo_l = wi, wg, wo
        hi = jnp.einsum("ecd,edf->ecf", recv, wi_l.astype(dtype))
        hg = jnp.einsum("ecd,edf->ecf", recv, wg_l.astype(dtype))
        out = jnp.einsum("ecf,efd->ecd", act(hg) * hi, wo_l.astype(dtype))

        # ------- return slots to sources + local combine ----------------------
        back = out.reshape(e_loc, n_sh, cap, d).swapaxes(0, 1).reshape(
            n_sh, e_loc * cap, d)
        ret = jax.lax.all_to_all(back, axis, split_axis=0, concat_axis=1,
                                 tiled=True)
        # received [owner][e][c] == global-expert-major == flat_idx layout
        ret = ret.reshape(n_exp * cap, d)
        y = jnp.zeros_like(xf)
        for j in range(k):
            y = y + ret[flat_idx[:, j]] * (gates[:, j, None].astype(dtype)
                                           * keep[:, j, None])
        # reassemble the full sequence from the model columns' slices
        y = jax.lax.all_gather(y, axis, axis=0, tiled=True)
        y = y.reshape(b_loc, s, d)

        # ------- arctic dense residual (TP over model, bf16 psum) ------------
        if dense_ok:
            if data_ok:
                dwi_l = jax.lax.all_gather(dwi, "data", axis=0, tiled=True)
                dwg_l = jax.lax.all_gather(dwg, "data", axis=0, tiled=True)
                dwo_l = jax.lax.all_gather(dwo, "data", axis=1, tiled=True)
            else:
                dwi_l, dwg_l, dwo_l = dwi, dwg, dwo
            hh = jnp.einsum("bsd,df->bsf", x_loc, dwi_l.astype(dtype))
            gg = jnp.einsum("bsd,df->bsf", x_loc, dwg_l.astype(dtype))
            dn = jnp.einsum("bsf,fd->bsd", act(gg) * hh, dwo_l.astype(dtype))
            y = y + jax.lax.psum(dn.astype(dtype), axis)
        return y, aux

    zeros = jnp.zeros((), dtype)
    dense_args = (p["dense"]["wi"]["kernel"], p["dense"]["wg"]["kernel"],
                  p["dense"]["wo"]["kernel"]) if dense_ok else \
        (zeros, zeros, zeros)
    dense_specs = (P("data" if data_ok else None, axis),
                   P("data" if data_ok else None, axis),
                   P(axis, "data" if data_ok else None)) if dense_ok else \
        (P(), P(), P())

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(dp, None, None), P(None, None), wspec, wspec,
                  P(axis, None, "data" if data_ok else None)) + dense_specs,
        out_specs=(P(dp, None, None), P()),
        check_vma=False)
    y, aux = fn(x, p["router"]["kernel"], p["wi"]["kernel"],
                p["wg"]["kernel"], p["wo"]["kernel"], *dense_args)
    y = checkpoint_name(y, TP_SAVE_NAME)
    if has_dense and not dense_ok:
        from repro.models.mlp import mlp
        y = y + mlp(p["dense"], cfg, x)
    return y, aux
