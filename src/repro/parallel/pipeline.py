"""Pipeline parallelism (GPipe) over a mesh axis via shard_map + ppermute.

The paper's Granite-20B recipe is 4TP × 4PP × 48DP with point-to-point PP
traffic on GDR; on TPU the analogue is a pipeline over the slow axis (the
``pod`` axis of the multi-pod mesh) with ``collective-permute`` hops, keeping
high-volume TP traffic on intra-pod ICI.

Implementation: stages hold a contiguous slice of layers (params sharded over
the stage axis); microbatches stream through with a rotating buffer.  The
backward pass is obtained by differentiating through the shard_map (GPipe
schedule: all forwards live, then backwards — paired with remat on the stage
body this is the classic memory/compute trade).
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.parallel.sharding import shard_map


def pipeline_forward(stage_fn: Callable, n_stages: int, axis: str):
    """Returns fn(stage_params, x_microbatches) for use INSIDE shard_map.

    stage_fn(stage_params, x) -> y applies this stage's layer slice.
    x_microbatches: (M, mb, ...) — all microbatches, present on every stage
    (stage 0 consumes them; other stages ignore and read their ppermute
    buffer).  Output: (M, mb, ...) results on the LAST stage (zeros
    elsewhere).
    """

    def run(stage_params, x_mb):
        s = jax.lax.axis_index(axis)
        m_total = x_mb.shape[0]
        t_total = m_total + n_stages - 1
        buf = jnp.zeros_like(x_mb[0])
        out = jnp.zeros_like(x_mb)
        fwd = [(i, i + 1) for i in range(n_stages - 1)]

        def body(carry, t):
            buf, out = carry
            m = t - s                       # microbatch index at this stage
            src = jnp.where(s == 0,
                            x_mb[jnp.clip(t, 0, m_total - 1)], buf)
            y = stage_fn(stage_params, src)
            active = jnp.logical_and(m >= 0, m < m_total)
            y = jnp.where(active, y, jnp.zeros_like(y))
            # deliver to next stage
            nxt = jax.lax.ppermute(y, axis, fwd)
            # last stage records its finished microbatch
            write_idx = jnp.clip(m, 0, m_total - 1)
            is_last = s == n_stages - 1
            upd = jnp.where(jnp.logical_and(active, is_last), y,
                            out[write_idx])
            out = jax.lax.dynamic_update_index_in_dim(out, upd, write_idx, 0)
            return (nxt, out), None

        (buf, out), _ = jax.lax.scan(body, (buf, out),
                                     jnp.arange(t_total))
        return out

    return run


def make_pipelined_apply(layer_fn: Callable, mesh: Mesh, axis: str,
                         n_microbatches: int,
                         remat: bool = True):
    """Builds apply(stacked_params, x) where stacked_params leaves have a
    leading layer dim (n_stages * layers_per_stage, ...) that gets sharded
    over ``axis`` (each stage's local block is its contiguous layer slice)
    and x: (batch, ...) is split into microbatches.

    The result lives on the last stage and is psum-broadcast so every stage
    returns it (convenient for loss computation).
    """
    n_stages = mesh.shape[axis]

    def stage_fn(stage_params, x):
        def one_layer(h, lp):
            return layer_fn(lp, h), None
        body = jax.checkpoint(one_layer) if remat else one_layer
        h, _ = jax.lax.scan(body, x, stage_params)
        return h

    pipe = pipeline_forward(stage_fn, n_stages, axis)

    def apply(params, x):
        b = x.shape[0]
        assert b % n_microbatches == 0
        x_mb = x.reshape(n_microbatches, b // n_microbatches, *x.shape[1:])

        def inner(params_local, x_loc):
            out = pipe(params_local, x_loc)
            # broadcast final result from the last stage to all stages
            s = jax.lax.axis_index(axis)
            out = jnp.where(s == n_stages - 1, out, jnp.zeros_like(out))
            return jax.lax.psum(out, axis)

        spec_params = jax.tree.map(lambda _: P(axis), params)
        fn = shard_map(inner, mesh=mesh,
                           in_specs=(spec_params, P()),
                           out_specs=P(),
                           check_vma=False)
        out = fn(params, x_mb)
        return out.reshape(b, *out.shape[2:])

    return apply
