from repro.parallel.mesh import make_production_mesh, make_mesh
from repro.parallel.sharding import (Rules, constrain, default_rules,
                                     logical_to_sharding, sharding_context,
                                     spec_for)

__all__ = ["make_production_mesh", "make_mesh", "Rules", "constrain",
           "default_rules", "logical_to_sharding", "sharding_context",
           "spec_for"]
