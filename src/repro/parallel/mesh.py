"""Mesh construction.  Functions only — importing this module never touches
jax device state (required so smoke tests see 1 device while the dry-run
sees 512 placeholder devices)."""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """The target deployment mesh: one 256-chip pod (16×16) or two pods (2×16×16).

    ``pod`` is the slow (DCN / inter-pod) axis; ``data`` and ``model`` are ICI.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Sequence[int], axes: Sequence[str]):
    assert len(shape) == len(axes)
    n = int(np.prod(shape))
    if n > len(jax.devices()):
        raise RuntimeError(
            f"mesh {tuple(shape)} needs {n} devices, have {len(jax.devices())}; "
            "the dry-run must set XLA_FLAGS=--xla_force_host_platform_device_count "
            "before any jax import")
    return jax.make_mesh(tuple(shape), tuple(axes))


def mesh_axis_size(mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.shape else 1


def data_axes(mesh) -> Tuple[str, ...]:
    """Axes that carry data parallelism (pod is an outer DP axis by default)."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)
