"""Logical-axis sharding: MaxText-style rules mapping logical names to mesh axes.

The key robustness property: a rule is *dropped per-tensor* when the dimension
size is not divisible by the mapped mesh-axis extent.  This is what lets one
rule set serve every architecture — e.g. ``heads -> model`` gives clean tensor
parallelism for llama3-405b (128H/16) and silently degrades to FSDP-sharded
weights with replicated head compute for arctic (56H ∤ 16).  The roofline
analysis then *shows* the replication cost, and the §Perf hillclimb addresses
it explicitly (see EXPERIMENTS.md).
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

# jax moved shard_map out of experimental in 0.5.x and renamed check_rep to
# check_vma; support both so the parallel modules run on the baked-in
# toolchain
if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:                                  # pragma: no cover - version dependent
    from jax.experimental.shard_map import shard_map as _shard_map_compat

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True, **kw):
        return _shard_map_compat(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=check_vma,
                                 **kw)

AxisVal = Union[None, str, Tuple[str, ...]]
Rules = Dict[str, AxisVal]

_ctx = threading.local()


def _axes_tuple(v: AxisVal) -> Tuple[str, ...]:
    if v is None:
        return ()
    if isinstance(v, str):
        return (v,)
    return tuple(v)


# ----------------------------------------------------------------- rule sets --

def default_rules(mesh_axes: Sequence[str], *, fsdp: bool = True,
                  shape_kind: str = "train", seq_sharded_cache: bool = False,
                  fsdp_over_pod: bool = False) -> Rules:
    """Baseline rules for the production mesh.

    - TP over ``model``: heads / mlp / experts / vocab.
    - FSDP (ZeRO-3) over ``data`` (optionally + ``pod``): the ``embed`` dim of
      every weight, and optimizer state.
    - DP over ``pod``+``data``: the batch dim.
    - decode: the KV-cache sequence dim is sharded over ``model``
      (flash-decoding: sharded-softmax partials combined by psum), and for
      long-context (``seq_sharded_cache``, batch=1) additionally over the DP
      axes with batch replicated.
    """
    has_pod = "pod" in mesh_axes
    dp: Tuple[str, ...] = ("pod", "data") if has_pod else ("data",)
    fsdp_ax: AxisVal = (dp if fsdp_over_pod else ("data",)) if fsdp else None
    rules: Rules = {
        # activations
        "batch": None if seq_sharded_cache else dp,
        "seq": None,
        "seq_sp": None,   # -> "model" enables sequence-parallel residual (§Perf)
        "kv_seq": (dp + ("model",)) if seq_sharded_cache else ("model",),
        # paged serving: the page pool's leading (P) dim shards P/n per chip
        # over the model axis (repro.parallel.pagedkv) — pinned pool bytes
        # scale down with the mesh, reads merge by partial softmax
        "kv_pages": "model",
        "enc_seq": None,
        # weights
        "vocab": "model",
        "embed": fsdp_ax,
        "heads": "model",
        "kv_heads": "model",
        "q_group": None,
        "head_dim": None,
        "mlp": "model",
        "expert": "model",
        "moe_group": None if seq_sharded_cache else dp,
        "mamba_inner": "model",
        "mamba_heads": "model",
        "mamba_conv": "model",
        "rwkv_heads": "model",
        "state": None,
        "conv": None,
        "lora": None,
        "norm": None,
        "layers": None,
        "stage": None,
        "img": None,
    }
    return rules


# --------------------------------------------------------------- resolution --

def spec_for(logical: Sequence[Optional[str]], shape: Sequence[int],
             rules: Rules, mesh: Mesh) -> PartitionSpec:
    """Resolve logical axes to a PartitionSpec, dropping non-divisible rules and
    never using the same mesh axis twice in one spec."""
    used: set = set()
    out: List[AxisVal] = []
    for dim, name in zip(shape, logical):
        val = rules.get(name) if name is not None else None
        axes = _axes_tuple(val)
        # keep only mesh axes that exist, are unused, and divide the dim
        kept: List[str] = []
        extent = 1
        for a in axes:
            if a in mesh.shape and a not in used:
                if dim % (extent * mesh.shape[a]) == 0:
                    kept.append(a)
                    extent *= mesh.shape[a]
        for a in kept:
            used.add(a)
        if not kept:
            out.append(None)
        elif len(kept) == 1:
            out.append(kept[0])
        else:
            out.append(tuple(kept))
    while out and out[-1] is None:
        out.pop()
    return PartitionSpec(*out)


def logical_to_sharding(axes_tree, abstract_tree, mesh: Mesh, rules: Rules):
    """Map a tree of logical-axes tuples (+ matching ShapeDtypeStructs) to
    NamedShardings."""
    def one(axes, aval):
        return NamedSharding(mesh, spec_for(axes, aval.shape, rules, mesh))
    return jax.tree.map(one, axes_tree, abstract_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(e, (str, type(None))) for e in x))


# ----------------------------------------------------------------- context ---

@contextmanager
def sharding_context(mesh: Optional[Mesh], rules: Optional[Rules]):
    """Activates ``constrain()`` inside jitted model code.  With no context (CPU
    smoke tests) ``constrain`` is a no-op."""
    prev = getattr(_ctx, "val", None)
    _ctx.val = (mesh, rules) if mesh is not None else None
    try:
        yield
    finally:
        _ctx.val = prev


def current_context():
    return getattr(_ctx, "val", None)


def constrain(x, logical: Sequence[Optional[str]]):
    """with_sharding_constraint by logical axes; no-op outside a context."""
    ctx = current_context()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = spec_for(logical, x.shape, rules, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
