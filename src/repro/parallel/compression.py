"""Gradient compression for data-parallel synchronization: int8 quantization
with error feedback (residual carried to the next step), exchanged by
all-gather so the wire format stays int8 end-to-end.

Wire accounting per device per step (N-way DP, G gradient floats):
  f32 ring all-reduce:            2 · 4B · G      = 8G bytes
  int8 AG-based compressed sync:  1B · G + 4B·G/N ≈ 1G bytes   (~8x less)

Validated against the dry-run HLO byte parser in tests; convergence impact
bounded by the error-feedback property (tested: quantization residual decays,
fixed-batch training still converges).
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.kernels.quant import dequantize, quantize_int8  # noqa: F401  (re-export: the int8 wire format lives in repro.kernels.quant, shared with the quantized KV page path)
from repro.parallel.sharding import shard_map


def compressed_allreduce_mean(x, err, *, axis: str):
    """Inside shard_map: int8 all-gather + local dequant-mean.
    Returns (mean_f32, new_err)."""
    q, scale, new_err = quantize_int8(x, err)
    qs = jax.lax.all_gather(q, axis)                 # (N, ...) int8 on wire
    scales = jax.lax.all_gather(scale, axis)         # (N,) f32
    mean = jnp.mean(qs.astype(jnp.float32)
                    * scales.reshape((-1,) + (1,) * x.ndim), axis=0)
    return mean, new_err


def make_compressed_grad_sync(mesh: Mesh, axis: str):
    """Returns sync(grads, err_state) -> (mean_grads, new_err_state) where
    grads are replicated pytrees whose leading batch-grad content is per-
    device partial gradients (pure-DP layout)."""

    def one(g, e):
        fn = shard_map(
            partial(compressed_allreduce_mean, axis=axis),
            mesh=mesh, in_specs=(P(axis), P(axis)),
            out_specs=(P(), P(axis)), check_vma=False)
        return fn(g, e)

    def sync(grads, err_state):
        flat_g, td = jax.tree.flatten(grads)
        flat_e = td.flatten_up_to(err_state)
        outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
        return (td.unflatten([o[0] for o in outs]),
                td.unflatten([o[1] for o in outs]))

    return sync


def init_error_state(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def wire_bytes_f32_allreduce(n_floats: int) -> int:
    return 8 * n_floats


def wire_bytes_int8_sync(n_floats: int, n_dp: int) -> int:
    return n_floats + (4 * n_floats) // max(n_dp, 1)
