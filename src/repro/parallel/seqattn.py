"""Sequence-sharded self-attention via shard_map (§Perf).

For architectures whose head count does not divide the ``model`` mesh axis
(arctic 56H, llama3.2/starcoder2 24H, granite-13b 40H) the pure-GSPMD
fallback replicates the whole attention block 16× on the model axis.  This
shard_map path shards the *query sequence* over the model axis instead:

    q, k, v sharded (B, S/16, ...);  K/V all-gathered (tiled) inside;
    each shard computes its query rows against the full K/V with causal
    masking from its global offset (axis_index-based, traced).

Compute and score-memory drop ~16×; the cost is the K/V all-gather
(2·S·KV·D bf16 per layer, tiny for GQA) and losing the static causal skip
(block masks applied everywhere → ≤2× upper-triangle waste, still ~8× net).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import current_context, shard_map

NEG_INF = -1e30


def _blockwise_dyn_offset(q, k, v, q_offset, q_chunk: int, kv_chunk: int):
    """Blockwise online-softmax attention with a *traced* query offset.
    q: (B, Sq, KV, G, D); k, v: (B, Skv, KV, D)."""
    b, sq, kvh, g, hd = q.shape
    skv = k.shape[1]
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    assert sq % q_chunk == 0 and skv % kv_chunk == 0
    q = q * (1.0 / math.sqrt(hd))
    n_kv = skv // kv_chunk
    k_b = k.reshape(b, n_kv, kv_chunk, kvh, hd).swapaxes(0, 1)
    v_b = v.reshape(b, n_kv, kv_chunk, kvh, hd).swapaxes(0, 1)
    kpos = (jnp.arange(n_kv)[:, None] * kv_chunk
            + jnp.arange(kv_chunk)[None, :])          # (n_kv, kvc)
    outs = []
    for i in range(sq // q_chunk):
        q_blk = jax.lax.dynamic_slice_in_dim(q, i * q_chunk, q_chunk, axis=1)
        qpos = q_offset + i * q_chunk + jnp.arange(q_chunk)   # traced offset

        def step(carry, xs):
            kj, vj, kp = xs
            m_prev, l_prev, acc = carry
            s = jnp.einsum("bqkgd,bskd->bkgqs", q_blk,
                           kj).astype(jnp.float32)
            s = jnp.where(qpos[None, None, None, :, None] >= kp[None, :],
                          s, NEG_INF)
            m_new = jnp.maximum(m_prev, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_prev - m_new)
            l_new = l_prev * corr + p.sum(axis=-1)
            pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(vj.dtype), vj)
            acc = acc * corr[..., None] + pv.astype(jnp.float32)
            return (m_new, l_new, acc), None

        init = (jnp.full((b, kvh, g, q_chunk), NEG_INF, jnp.float32),
                jnp.zeros((b, kvh, g, q_chunk), jnp.float32),
                jnp.zeros((b, kvh, g, q_chunk, hd), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(step, init, (k_b, v_b, kpos))
        o = acc / jnp.maximum(l, 1e-30)[..., None]
        outs.append(o.transpose(0, 3, 1, 2, 4).astype(q.dtype))
    return jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]


def seq_sharded_attention(q, k, v, *, axis: str = "model",
                          q_chunk: int = 512, kv_chunk: int = 512):
    """q: (B,S,KV,G,D); k, v: (B,S,KV,D), all logically unsharded on entry.
    Runs under the active sharding context; no-op fallback without one."""
    ctx = current_context()
    if ctx is None:
        return _blockwise_dyn_offset(q, k, v, jnp.int32(0), q_chunk, kv_chunk)
    mesh, rules = ctx
    if axis not in mesh.shape or q.shape[1] % mesh.shape[axis] != 0:
        return _blockwise_dyn_offset(q, k, v, jnp.int32(0), q_chunk, kv_chunk)
    n_shards = mesh.shape[axis]
    s_loc = q.shape[1] // n_shards
    batch_axes = rules.get("batch")

    qspec = P(batch_axes, axis, None, None, None)
    kvspec = P(batch_axes, axis, None, None)

    def body(ql, kl, vl):
        kf = jax.lax.all_gather(kl, axis, axis=1, tiled=True)
        vf = jax.lax.all_gather(vl, axis, axis=1, tiled=True)
        offset = jax.lax.axis_index(axis) * s_loc
        return _blockwise_dyn_offset(ql, kf, vf, offset,
                                     min(q_chunk, s_loc), kv_chunk)

    fn = shard_map(body, mesh=mesh, in_specs=(qspec, kvspec, kvspec),
                       out_specs=qspec, check_vma=False)
    return fn(q, k, v)
